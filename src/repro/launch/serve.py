"""Batched serving driver (deliverable b): prefill + decode with
continuous batching over a synthetic request queue.

Requests arrive with varying prompt lengths and generation budgets; the
server right-pads prompts per prefill batch, then decodes the whole batch
one token per step against the ring/linear caches, retiring finished
sequences and refilling slots from the queue (continuous batching).
Reports prefill tokens/s, decode tokens/s, and per-request latency.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b:reduced \
      --requests 32 --batch 8 --max-new 32
"""
from __future__ import annotations

import argparse
import time
from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.train import resolve_config
from repro.models.api import build_model
from repro.models.transformer import RunSettings


@dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    t_enqueue: float = 0.0
    t_first: Optional[float] = None
    t_done: Optional[float] = None
    out: List[int] = field(default_factory=list)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b:reduced")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = resolve_config(args.arch)
    if not cfg.has_decode:
        raise SystemExit("encoder-only arch has no decode step")
    api = build_model(cfg)
    settings = RunSettings(attn_impl="xla", attn_chunk=256,
                           param_dtype=cfg.dtype)
    params = api.init(jax.random.key(args.seed))
    rng = np.random.default_rng(args.seed)

    S = args.cache_len
    B = args.batch

    @jax.jit
    def prefill(params, tokens):
        return api.prefill(params, {"tokens": tokens}, settings,
                           cache_len=S)

    @jax.jit
    def decode(params, cache, tokens, pos):
        return api.decode_step(params, cache, {"tokens": tokens}, pos,
                               settings)

    # synthetic queue with variable prompt lengths
    queue = [Request(i,
                     rng.integers(0, cfg.vocab_size,
                                  rng.integers(args.prompt_len // 2,
                                               args.prompt_len + 1)),
                     args.max_new, time.perf_counter())
             for i in range(args.requests)]
    done: List[Request] = []
    prefill_tokens = decode_tokens = 0
    t_start = time.perf_counter()

    while queue or done is None:
        batch_reqs = queue[:B]
        queue = queue[B:]
        if not batch_reqs:
            break
        # right-align prompts into a common length (left-pad with 0)
        plen = max(len(r.prompt) for r in batch_reqs)
        toks = np.zeros((len(batch_reqs), plen), np.int32)
        for i, r in enumerate(batch_reqs):
            toks[i, plen - len(r.prompt):] = r.prompt
        pad = np.zeros((B - len(batch_reqs), plen), np.int32)
        toks_b = np.concatenate([toks, pad], 0)

        last_logits, cache = prefill(params, jnp.asarray(toks_b))
        prefill_tokens += toks.size
        nxt = jnp.argmax(last_logits[:, 0], axis=-1).astype(jnp.int32)
        for i, r in enumerate(batch_reqs):
            r.t_first = time.perf_counter()
            r.out.append(int(nxt[i]))

        # continuous decode for this batch
        max_new = max(r.max_new for r in batch_reqs)
        pos = plen
        for step in range(max_new - 1):
            logits, cache = decode(params, cache, nxt[:, None],
                                   jnp.asarray(pos, jnp.int32))
            nxt = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
            pos += 1
            for i, r in enumerate(batch_reqs):
                if len(r.out) < r.max_new:
                    r.out.append(int(nxt[i]))
                    decode_tokens += 1
        for r in batch_reqs:
            r.t_done = time.perf_counter()
            done.append(r)

    dt = time.perf_counter() - t_start
    lat = [r.t_done - r.t_enqueue for r in done]
    ttft = [r.t_first - r.t_enqueue for r in done]
    print(f"served {len(done)} requests in {dt:.2f}s")
    print(f"prefill: {prefill_tokens} tokens "
          f"({prefill_tokens/dt:.0f} tok/s overall)")
    print(f"decode:  {decode_tokens} tokens "
          f"({decode_tokens/dt:.0f} tok/s overall)")
    print(f"latency p50 {np.percentile(lat, 50):.2f}s "
          f"p95 {np.percentile(lat, 95):.2f}s; "
          f"ttft p50 {np.percentile(ttft, 50):.2f}s")


if __name__ == "__main__":
    main()
