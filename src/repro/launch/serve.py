"""Serving driver: continuous batching over a paged (or dense) KV
cache (repro.kvcache).

Slots turn over individually — a retiring sequence's slot refills from
the resume/new queues the same step, while the other slots keep
decoding. With `--cache paged` the KV lives in fixed-size device pages;
parked sequences (quantum preemption, `--quantum`) evict their pages
through the activation spool to SSD and prefetch them back under the
other slots' decode compute, so live sequences can exceed the device
slot count. `--cache dense` is the classic per-slot dense layout at the
same attention extent — same logits bitwise, concurrency capped at the
slot count.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b:reduced \
      --requests 32 --batch 8 --max-new 32
  PYTHONPATH=src python -m repro.launch.serve --arch small-gpt \
      --cache paged --quantum 8 --trace serve.trace.json

The old driver (batch-at-a-time, decode the whole batch to completion)
had a dead `while queue or done is None` loop clause and two
accounting skews — the first sampled token of every request was
dropped from the token counts and idle padding slots were billed as
decode work; the scheduler fixes all three (repro.kvcache.scheduler).
"""
from __future__ import annotations

import argparse
import json
import shutil

import jax
import numpy as np

from repro import obs
from repro.configs.base import SpoolIoConfig
from repro.core.spool import build_spool
from repro.kvcache import KVCacheConfig, Server, build_manager
from repro.launch.cacheargs import add_cache_args, cache_overrides
from repro.launch.train import resolve_config
from repro.models.api import build_model
from repro.models.transformer import RunSettings


def build_runtime(arch: str, seed: int = 0):
    """Model api + initialized params + decode settings for an arch."""
    cfg = resolve_config(arch)
    if not cfg.has_decode:
        raise SystemExit("encoder-only arch has no decode step")
    api = build_model(cfg)
    settings = RunSettings(attn_impl="xla", attn_chunk=256,
                           param_dtype=cfg.dtype)
    params = api.init(jax.random.key(seed))
    return cfg, api, params, settings


def build_kv_spool(backend: str = "fs", directory=None,
                   codec: str = "byteplane", **io_kwargs):
    """A spool for KV pages: same data plane as training activations
    (bufpool + aio/fs + byteplane), but with the small-tensor bypass off
    — KV pages are small and must actually hit storage. Extra kwargs are
    `SpoolIoConfig` fields (the --cache-* family lands here). Returns
    (spool, owned_tmpdirs)."""
    io_cfg = SpoolIoConfig(backend=backend, directory=directory,
                           codec=codec, **io_kwargs)
    return build_spool(io_cfg, min_offload_elements=0)


def synth_requests(server: Server, n: int, prompt_len: int,
                   max_new: int, vocab: int, seed: int) -> None:
    """Submit the synthetic trace: variable prompt lengths in
    [prompt_len//2, prompt_len], fixed generation budget. Deterministic
    in the seed — the parity tests replay the same trace paged vs
    dense."""
    rng = np.random.default_rng(seed)
    for _ in range(n):
        plen = int(rng.integers(max(1, prompt_len // 2), prompt_len + 1))
        server.submit(rng.integers(0, vocab, plen), max_new)


def make_server(api, params, settings, kvcfg: KVCacheConfig, *,
                kind: str = "paged", n_slots: int = 8, spool=None,
                record_logits: bool = False) -> Server:
    cache = build_manager(kind, api, params, settings, kvcfg, n_slots,
                          spool)
    return Server(cache, record_logits=record_logits)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b:reduced")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--batch", type=int, default=8,
                    help="decode slots")
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--cache-len", type=int, default=128,
                    help="max logical sequence length (prompt + gen)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--cache", choices=("paged", "dense"),
                    default="paged")
    ap.add_argument("--page-tokens", type=int, default=16,
                    help="tokens per KV page")
    ap.add_argument("--pool-pages", type=int, default=0,
                    help="device page-pool size (0: worst-case sizing)")
    ap.add_argument("--quantum", type=int, default=0,
                    help="decode tokens before preemption (0: run to "
                         "retirement)")
    ap.add_argument("--max-live", type=int, default=0,
                    help="admission cap on live sequences (0: none)")
    ap.add_argument("--prefetch-depth", type=int, default=2,
                    help="parked sequences prefetched ahead of refill")
    ap.add_argument("--kv-backend", default="fs",
                    choices=("fs", "aio", "mem", "managed"),
                    help="spool storage for evicted pages; 'managed' "
                         "is the repro.cache storage brain (see the "
                         "--cache-* family)")
    ap.add_argument("--kv-dir", default=None,
                    help="spool directory (default: fresh temp dir)")
    ap.add_argument("--kv-codec", default="byteplane",
                    choices=("raw", "zlib", "byteplane"))
    ap.add_argument("--trace", default=None,
                    help="write a Perfetto trace (kv.* page events, "
                         "serve.* scheduling, io.* spool lanes)")
    ap.add_argument("--json", dest="json_out", default=None,
                    help="write the serve report as JSON")
    add_cache_args(ap)
    args = ap.parse_args()

    if args.trace:
        obs.enable()
    cfg, api, params, settings = build_runtime(args.arch, args.seed)
    kvcfg = KVCacheConfig(
        page_tokens=args.page_tokens, pool_pages=args.pool_pages,
        max_seq_len=args.cache_len, prefetch_depth=args.prefetch_depth,
        quantum=args.quantum, max_live=args.max_live)

    spool = None
    owned = []
    if args.cache == "paged":
        cache_ov = cache_overrides(args)
        kv_backend = cache_ov.pop("backend", args.kv_backend)
        spool, owned = build_kv_spool(kv_backend, args.kv_dir,
                                      args.kv_codec, **cache_ov)
    try:
        server = make_server(api, params, settings, kvcfg,
                             kind=args.cache, n_slots=args.batch,
                             spool=spool)
        synth_requests(server, args.requests, args.prompt_len,
                       args.max_new, cfg.vocab_size, args.seed)
        report = server.run()
    finally:
        if spool is not None:
            spool.close()
        for d in owned:
            shutil.rmtree(d, ignore_errors=True)

    r = report
    print(f"served {r.requests} requests on {r.n_slots} slots "
          f"({r.cache_kind} cache) in {r.wall_time_s:.2f}s")
    print(f"prefill: {r.prompt_tokens} prompt tokens; "
          f"generated: {r.generated_tokens} tokens "
          f"({r.gen_tok_s:.0f} tok/s overall)")
    print(f"decode:  {r.decode_slot_tokens} slot-tokens over "
          f"{r.decode_steps} steps ({r.decode_tok_s:.0f} tok/s, "
          f"occupancy {r.slot_occupancy:.2f})")
    print(f"live:    peak {r.peak_live} mean {r.mean_live:.1f} "
          f"(preemptions {r.preemptions})")
    print(f"latency: ttft p50 {r.ttft_p50_ms:.1f}ms "
          f"p99 {r.ttft_p99_ms:.1f}ms; inter-token p50 "
          f"{r.itl_p50_ms:.1f}ms p95 {r.itl_p95_ms:.1f}ms "
          f"p99 {r.itl_p99_ms:.1f}ms")
    if r.kv.get("evictions") or r.kv.get("pages_allocated"):
        print(f"kv:      {r.kv['pages_allocated']} pages allocated, "
              f"{r.kv['pages_evicted']} evicted / "
              f"{r.kv['pages_restored']} restored "
              f"({r.kv['evictions']} evictions, "
              f"{r.kv['restores']} restores)")
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(report.as_dict(), f, indent=2, sort_keys=True)
        print(f"report -> {args.json_out}")
    if args.trace:
        path = obs.write_chrome_trace(args.trace, obs.get_tracer())
        print(f"trace -> {path}")


if __name__ == "__main__":
    main()
