"""Jitted step builders shared by the dry-run, the trainer and the server.

Each builder returns (step_fn, example_args) where example_args is a tree of
ShapeDtypeStructs with NamedShardings attached — `jax.jit(step_fn).lower(
*example_args)` is everything the dry-run needs, and the trainer feeds real
arrays with the same shardings.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.api import ModelApi
from repro.models.transformer import RunSettings
from repro.optim.optimizers import Optimizer
from repro.parallel.sharding import (MeshAxes, batch_specs, cache_specs,
                                     param_specs, with_sharding)

# Serving keeps weights TP-only (no per-step all-gather) while they fit;
# above this per-chip budget the dry-run falls back to fsdp sharding.
SERVE_TP_ONLY_BUDGET = 8 << 30


def count_params(params_shapes, *, exclude=("embed", "pos_embed")) -> int:
    """Number of parameters, excluding lookup-only tables (for 6ND)."""
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params_shapes)[0]:
        names = [getattr(k, "key", None) for k in path]
        if any(n in exclude for n in names):
            continue
        total += leaf.size
    return total


def active_param_count(cfg: ModelConfig, params_shapes) -> int:
    """Active params per token: for MoE, only top_k of the expert stacks
    (plus shared experts / router / attention) touch a given token."""
    n = count_params(params_shapes)
    if not cfg.moe_num_experts:
        return n
    moe = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params_shapes)[0]:
        names = [getattr(k, "key", None) for k in path]
        if "moe" in names and any(s in names
                                  for s in ("w_in", "w_gate", "w_out")):
            moe += leaf.size
    return n - moe + (moe * cfg.moe_top_k) // cfg.moe_num_experts


def param_bytes(params_shapes) -> int:
    return sum(l.size * l.dtype.itemsize
               for l in jax.tree.leaves(params_shapes))


def build_settings(cfg: ModelConfig, mesh, axes: MeshAxes, *, kind: str,
                   activation_policy: Optional[str] = None,
                   attn_chunk: int = 1024,
                   ce_chunk: int = 512) -> RunSettings:
    policy = activation_policy or ("offload" if kind == "train" else "keep")
    is_moe = cfg.moe_num_experts > 0
    return RunSettings(
        attn_impl="xla", attn_chunk=attn_chunk,
        activation_policy=policy, offload_names=("blk_in",),
        mesh=mesh,
        ep_axis="model" if is_moe else None,
        tp_axis=axes.tp,
        dp_axes=axes.dp,
        param_dtype=cfg.dtype,
        ce_chunk=ce_chunk if kind == "train" else 0)


def make_host_train_step(api: ModelApi, optimizer: Optimizer,
                         settings: RunSettings, *, mesh=None,
                         axes: Optional[MeshAxes] = None) -> Callable:
    """Whole-step jitted train step for the single-host jit engine —
    shared by `repro.session.TrainSession` and `repro.launch.train`.
    Signature matches what TrainLoop drives:
    (params, opt_state, batch) -> (params, opt_state, metrics).

    With the "spool" activation policy (per-layer offloading via
    repro.core.hooks), the optimizer's step counter is threaded into the
    batch under the reserved "_spool_step" key — the traced scalar the
    hooks key their spool step-leases on.

    With a `mesh`, each numpy batch from the loader is placed with
    dp-sharded batch specs before entering the jitted step, so the
    program partitions across the mesh (params/opt state placement is
    the caller's job — `TrainSession.init` device_puts them); the spool
    hooks then run their callbacks per shard under a shard_map."""
    hooked = (settings.activation_policy == "spool"
              and settings.hook_bridge is not None)

    @jax.jit
    def step_fn(params, opt_state, batch):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        if hooked:
            batch["_spool_step"] = opt_state.step
        (_, metrics), grads = jax.value_and_grad(
            api.loss, has_aux=True)(params, batch, settings)
        params, opt_state = optimizer.update(grads, opt_state, params)
        return params, opt_state, metrics

    if mesh is None:
        return step_fn
    axes = axes or MeshAxes()

    def sharded_step(params, opt_state, batch):
        arrs = {k: np.asarray(v) for k, v in batch.items()}
        specs = batch_specs(arrs, mesh, axes)
        batch = jax.device_put(
            arrs, {k: NamedSharding(mesh, specs[k]) for k in arrs})
        return step_fn(params, opt_state, batch)

    return sharded_step


def make_overlap_train_step(api: ModelApi, optimizer: Optimizer,
                            settings: RunSettings, opt_bridge, *,
                            mesh=None,
                            axes: Optional[MeshAxes] = None) -> Callable:
    """Eager-overlap variant of `make_host_train_step`.

    The jitted program computes only (metrics, grads): the per-layer
    grad taps (`settings.opt_sink`, see repro.core.hooks) stream each
    scanned layer's gradients to the OptBridge as backward produces
    them, and the bridge's side stream fetches/updates/stages that
    layer's opt-state moments while XLA is still in the next layer's
    backward. The Python wrapper keeps the TrainLoop contract
    ``(params, opt_state, batch) -> (params, opt_state, metrics)``:
    it joins the side stream only after blocking on the grads (by then
    every tap has fired — the taps' tokens are data dependencies of the
    grads) and applies the non-scanned rest of the tree on the main
    thread with the same kernels. `opt_state` is the bridge's light
    ``(step, None, None)`` husk after the first step; the incoming full
    state seeds the bridge lazily (init and resume both land here)."""
    axes = axes or MeshAxes()

    @jax.jit
    def grad_fn(params, step, batch):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        batch["_spool_step"] = step
        (_, metrics), grads = jax.value_and_grad(
            api.loss, has_aux=True)(params, batch, settings)
        return metrics, grads

    def step_fn(params, opt_state, batch):
        opt_bridge.ensure_seeded(opt_state, params)
        step_i = int(opt_state.step)
        opt_bridge.begin_step(params, step_i)
        if mesh is not None:
            arrs = {k: np.asarray(v) for k, v in batch.items()}
            specs = batch_specs(arrs, mesh, axes)
            batch = jax.device_put(
                arrs, {k: NamedSharding(mesh, specs[k]) for k in arrs})
        metrics, grads = grad_fn(params,
                                 jnp.asarray(step_i, jnp.int32), batch)
        jax.block_until_ready(grads)
        new_params, new_opt = opt_bridge.finish_step(params, grads)
        return new_params, new_opt, metrics

    return step_fn


@dataclass
class StepBundle:
    fn: Callable                  # jit-able step function
    args: Tuple[Any, ...]         # ShapeDtypeStructs with shardings
    out_shardings: Any            # or None (auto)
    settings: RunSettings
    param_specs: Any
    n_params: int                 # for 6ND (excludes lookup tables)
    n_active: int
    tokens_per_step: int
    fsdp: bool


def _params_sds(api: ModelApi):
    return jax.eval_shape(api.init, jax.random.key(0))


def make_train_step(api: ModelApi, mesh, axes: MeshAxes,
                    optimizer: Optimizer, shape: ShapeConfig,
                    *, activation_policy: Optional[str] = None,
                    ce_chunk: int = 512,
                    settings: Optional[RunSettings] = None) -> StepBundle:
    cfg = api.cfg
    settings = settings or build_settings(
        cfg, mesh, axes, kind="train", activation_policy=activation_policy,
        ce_chunk=ce_chunk)

    p_sds = _params_sds(api)
    p_specs = param_specs(cfg, p_sds, mesh, axes, fsdp=True)
    params = with_sharding(p_sds, p_specs, mesh)
    o_sds = jax.eval_shape(optimizer.init, p_sds)
    # moments inherit the param specs (ZeRO: fully sharded optimizer state)
    o_specs = type(o_sds)(
        step=P(),
        mu=None if o_sds.mu is None else p_specs,
        nu=None if o_sds.nu is None else p_specs)
    opt_state = with_sharding(o_sds, o_specs, mesh)
    b_sds = api.input_specs(shape)["batch"]
    b_specs = batch_specs(b_sds, mesh, axes)
    batch = with_sharding(b_sds, b_specs, mesh)

    p_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs,
                        is_leaf=lambda x: isinstance(x, P))
    o_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), o_specs,
                        is_leaf=lambda x: isinstance(x, P))

    # NOTE: output layouts are pinned with with_sharding_constraint instead
    # of jit(out_shardings=...): explicit out_shardings on a module that
    # contains memory-space annotations (the pinned_host activation
    # offload) trips XLA's SPMD partitioner ("side-effect ops cannot be
    # replicated" on annotate_device_placement custom-calls).
    def train_step(params, opt_state, batch):
        if settings.activation_policy == "spool" \
                and settings.hook_bridge is not None:
            # per-layer spool hooks; on a multi-device mesh the hooks
            # wrap their io_callbacks in a shard_map (GSPMD cannot
            # partition a bare io_callback), so every device streams
            # its local residual shard — see repro.core.hooks
            batch = dict(batch)
            batch["_spool_step"] = opt_state.step
        (_, metrics), grads = jax.value_and_grad(
            api.loss, has_aux=True)(params, batch, settings)
        params, opt_state = optimizer.update(grads, opt_state, params)
        params = jax.lax.with_sharding_constraint(params, p_sh)
        opt_state = jax.lax.with_sharding_constraint(opt_state, o_sh)
        return params, opt_state, metrics

    return StepBundle(
        fn=train_step, args=(params, opt_state, batch),
        out_shardings=None,
        settings=settings, param_specs=p_specs,
        n_params=count_params(p_sds),
        n_active=active_param_count(cfg, p_sds),
        tokens_per_step=shape.global_batch * shape.seq_len, fsdp=True)


def _serve_fsdp(mesh, axes: MeshAxes, p_sds) -> bool:
    per_chip = param_bytes(p_sds) // axes.tp_size(mesh)
    return per_chip > SERVE_TP_ONLY_BUDGET


def make_prefill_step(api: ModelApi, mesh, axes: MeshAxes,
                      shape: ShapeConfig,
                      *, settings: Optional[RunSettings] = None) \
        -> StepBundle:
    cfg = api.cfg
    settings = settings or build_settings(cfg, mesh, axes, kind="prefill")
    emit_cache = cfg.has_decode

    def prefill_step(params, batch):
        if emit_cache:
            return api.prefill(params, batch, settings,
                               cache_len=shape.seq_len)
        logits, _ = api.forward(params, batch, settings)
        return logits

    p_sds = _params_sds(api)
    fsdp = _serve_fsdp(mesh, axes, p_sds)
    p_specs = param_specs(cfg, p_sds, mesh, axes, fsdp=fsdp)
    params = with_sharding(p_sds, p_specs, mesh)
    b_sds = api.input_specs(shape, for_loss=False)["batch"]
    batch = with_sharding(b_sds, batch_specs(b_sds, mesh, axes), mesh)
    return StepBundle(
        fn=prefill_step, args=(params, batch), out_shardings=None,
        settings=settings, param_specs=p_specs,
        n_params=count_params(p_sds),
        n_active=active_param_count(cfg, p_sds),
        tokens_per_step=shape.global_batch * shape.seq_len, fsdp=fsdp)


def make_decode_step(api: ModelApi, mesh, axes: MeshAxes,
                     shape: ShapeConfig,
                     *, settings: Optional[RunSettings] = None) \
        -> StepBundle:
    cfg = api.cfg
    settings = settings or build_settings(cfg, mesh, axes, kind="decode")

    def decode_step(params, cache, batch, pos):
        return api.decode_step(params, cache, batch, pos, settings)

    p_sds = _params_sds(api)
    fsdp = _serve_fsdp(mesh, axes, p_sds)
    p_specs = param_specs(cfg, p_sds, mesh, axes, fsdp=fsdp)
    params = with_sharding(p_sds, p_specs, mesh)
    specs = api.input_specs(shape)
    b_sds, c_sds = specs["batch"], specs["cache"]
    batch = with_sharding(b_sds, batch_specs(b_sds, mesh, axes), mesh)
    cache = with_sharding(c_sds, cache_specs(c_sds, mesh, axes), mesh)
    pos = jax.ShapeDtypeStruct((), jnp.int32,
                               sharding=NamedSharding(mesh, P()))
    return StepBundle(
        fn=decode_step, args=(params, cache, batch, pos),
        out_shardings=None, settings=settings, param_specs=p_specs,
        n_params=count_params(p_sds),
        n_active=active_param_count(cfg, p_sds),
        tokens_per_step=shape.global_batch, fsdp=fsdp)


def make_step(api: ModelApi, mesh, axes: MeshAxes, shape: ShapeConfig,
              optimizer: Optional[Optimizer] = None, **kw) -> StepBundle:
    if shape.kind == "train":
        assert optimizer is not None
        return make_train_step(api, mesh, axes, optimizer, shape, **kw)
    if shape.kind == "prefill":
        return make_prefill_step(api, mesh, axes, shape, **kw)
    return make_decode_step(api, mesh, axes, shape, **kw)
