"""llama-3.2-vision-90b [vlm] — cross-attention image layers every 5th layer.
[hf:meta-llama/Llama-3.2-*-Vision]

The vision frontend is a STUB per the assignment: input_specs() provides
precomputed patch embeddings of shape (batch, encoder_seq_len, d_model).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    num_layers=100,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    cross_attn_period=5,         # every 5th layer cross-attends to patches
    encoder_seq_len=1024,
    act="silu",
).validate()
