"""mamba2-2.7b [ssm] — SSD (state-space duality), attention-free.
[arXiv:2405.21060]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state_dim=128,
    ssm_expand=2,
    ssm_head_dim=64,             # n_heads = expand * d_model / head_dim = 80
    ssm_chunk=128,
    ssm_conv_width=4,
    use_rope=False,
).validate()
