"""Registry mapping --arch ids to ModelConfigs, and the assigned 40-cell
(arch x shape) grid with its documented skips."""
from __future__ import annotations

import importlib
from typing import Dict, List, Optional, Tuple

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig

_ARCH_MODULES = {
    "qwen2.5-3b": "repro.configs.qwen2_5_3b",
    "gemma2-27b": "repro.configs.gemma2_27b",
    "granite-8b": "repro.configs.granite_8b",
    "granite-3-2b": "repro.configs.granite_3_2b",
    "recurrentgemma-9b": "repro.configs.recurrentgemma_9b",
    "kimi-k2-1t-a32b": "repro.configs.kimi_k2_1t_a32b",
    "llama4-scout-17b-a16e": "repro.configs.llama4_scout_17b_a16e",
    "mamba2-2.7b": "repro.configs.mamba2_2_7b",
    "llama-3.2-vision-90b": "repro.configs.llama_3_2_vision_90b",
    "hubert-xlarge": "repro.configs.hubert_xlarge",
}

ARCH_IDS: List[str] = list(_ARCH_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    return importlib.import_module(_ARCH_MODULES[arch]).CONFIG


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def cell_skip_reason(cfg: ModelConfig, shape: ShapeConfig) -> Optional[str]:
    """Why an (arch x shape) cell is skipped, or None if runnable.

    Documented in DESIGN.md §Arch-applicability:
      - encoder-only archs have no decode step;
      - long_500k needs sub-quadratic attention end to end.
    """
    if shape.kind == "decode" and not cfg.has_decode:
        return "encoder-only arch has no autoregressive decode step"
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return "full-attention arch: 524k decode requires sub-quadratic blocks"
    return None


def grid() -> List[Tuple[str, str, Optional[str]]]:
    """All 40 assigned cells as (arch, shape, skip_reason_or_None)."""
    out = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            out.append((arch, shape.name, cell_skip_reason(cfg, shape)))
    return out
