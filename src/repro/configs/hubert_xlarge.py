"""hubert-xlarge [audio] — encoder-only transformer backbone (w2v2-style).
[arXiv:2106.07447]

The audio frontend is a STUB per the assignment: input_specs() provides
precomputed frame embeddings of shape (batch, seq, d_model). Encoder-only:
no decode shapes (decode_32k / long_500k are skipped).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab_size=504,
    causal=False,                # encoder-only, bidirectional
    use_rope=False,              # learned/conv positions in the stub frontend
    input_kind="embeddings",
    act="gelu",
    mlp_glu=False,
).validate()
