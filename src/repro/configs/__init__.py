from repro.configs.base import (SHAPES, ModelConfig, ShapeConfig,
                                SpoolIoConfig, reduced)
from repro.configs.registry import (ARCH_IDS, cell_skip_reason, get_config,
                                    get_shape, grid)

__all__ = [
    "ModelConfig", "ShapeConfig", "SpoolIoConfig", "SHAPES", "reduced",
    "ARCH_IDS", "get_config", "get_shape", "grid", "cell_skip_reason",
]
