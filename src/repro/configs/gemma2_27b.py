"""gemma2-27b [dense] — local+global alternating attention, logit softcaps.
[arXiv:2408.00118]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    num_layers=46,
    d_model=4608,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab_size=256000,
    sliding_window=4096,
    local_global_period=2,       # local, global, local, global, ...
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    act="gelu",
    post_block_norm=True,
    tie_embeddings=True,
    scale_embed=True,
).validate()
