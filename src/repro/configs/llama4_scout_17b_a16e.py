"""llama4-scout-17b-a16e [moe] — 16 experts top-1 + shared, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E]

NOTE: 40 query heads do not divide the 16-way model axis; the sharding rules
fall back to row-parallel attention projections for this arch (see
repro/parallel/sharding.py).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,                   # per-expert FF width
    vocab_size=202048,
    moe_num_experts=16,
    moe_top_k=1,
    moe_shared_experts=1,
    act="silu",
).validate()
