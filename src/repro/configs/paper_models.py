"""The paper's own evaluation set (§4.1): BERT (encoder-only), GPT
(decoder-only), T5 (encoder-decoder), at the paper's geometry
(hidden 8192..16384, head_dim 128, seq 1024) plus small CPU-runnable
variants used by the benchmark harness on this container.
"""
from repro.configs.base import ModelConfig


def bert(hidden: int, layers: int, vocab: int = 30592) -> ModelConfig:
    return ModelConfig(
        name=f"bert-h{hidden}-l{layers}",
        family="dense",
        num_layers=layers,
        d_model=hidden,
        num_heads=hidden // 128,
        num_kv_heads=hidden // 128,
        head_dim=128,
        d_ff=4 * hidden,
        vocab_size=vocab,
        causal=False,
        use_rope=False,
        act="gelu",
        mlp_glu=False,
    ).validate()


def gpt(hidden: int, layers: int, vocab: int = 50304) -> ModelConfig:
    return ModelConfig(
        name=f"gpt-h{hidden}-l{layers}",
        family="dense",
        num_layers=layers,
        d_model=hidden,
        num_heads=hidden // 128,
        num_kv_heads=hidden // 128,
        head_dim=128,
        d_ff=4 * hidden,
        vocab_size=vocab,
        act="gelu",
        mlp_glu=False,
    ).validate()


def t5(hidden: int, layers: int, vocab: int = 32128) -> ModelConfig:
    # "For T5, the number of decoders is half of the total number of layers,
    # rounded down." (§4.1)
    return ModelConfig(
        name=f"t5-h{hidden}-l{layers}",
        family="encdec",
        num_layers=layers - layers // 2,   # encoder layers
        num_decoder_layers=layers // 2,
        d_model=hidden,
        num_heads=hidden // 128,
        num_kv_heads=hidden // 128,
        head_dim=128,
        d_ff=4 * hidden,
        vocab_size=vocab,
        encoder_seq_len=0,
        act="gelu",
        use_rope=False,
    ).validate()


# The paper's three (hidden, layers) scenarios per model (§4.2, Fig. 10).
PAPER_SCENARIOS = [(8192, 4), (12288, 3), (16384, 2)]

# CPU-runnable variants of the same families for this container's benchmarks.
SMALL_SCENARIOS = [(256, 4), (384, 3), (512, 2)]


def _shrink_heads(c: ModelConfig, hidden: int) -> ModelConfig:
    import dataclasses
    h = max(2, hidden // 64)
    return dataclasses.replace(c, num_heads=h, num_kv_heads=h, head_dim=64)


def small_bert(hidden: int = 256, layers: int = 4) -> ModelConfig:
    return _shrink_heads(bert(hidden, layers, vocab=2048), hidden)


def small_gpt(hidden: int = 256, layers: int = 4) -> ModelConfig:
    return _shrink_heads(gpt(hidden, layers, vocab=2048), hidden)


def small_t5(hidden: int = 256, layers: int = 4) -> ModelConfig:
    return _shrink_heads(t5(hidden, layers, vocab=2048), hidden)
