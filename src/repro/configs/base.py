"""Config schema for all supported architectures.

Every assigned architecture (and the paper's own BERT/GPT/T5 evaluation
models) is described by a single `ModelConfig`. The config is purely
declarative; `repro.models.api.build_model` turns it into init/apply
functions and `repro.parallel.sharding` turns it into PartitionSpec trees.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Optional, Tuple

# Families. "dense" covers every pure-attention decoder; encoder-only and
# encoder-decoder are orthogonal flags so hubert ("audio") and T5 reuse the
# same transformer substrate.
FAMILIES = ("dense", "moe", "ssm", "hybrid", "vlm", "audio", "encdec")


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                      # 0 -> d_model // num_heads

    # --- attention ---
    causal: bool = True                    # False for encoder-only
    qkv_bias: bool = False
    sliding_window: int = 0                # 0 -> full attention
    # layer i is local (sliding window) iff local_global_period > 0 and
    # i % local_global_period != local_global_period - 1 (gemma2: period 2)
    local_global_period: int = 0
    attn_logit_softcap: float = 0.0        # 0 -> disabled
    final_logit_softcap: float = 0.0
    rope_theta: float = 10000.0
    use_rope: bool = True

    # --- MoE ---
    moe_num_experts: int = 0
    moe_top_k: int = 0
    moe_shared_experts: int = 0            # shared (always-on) experts
    moe_first_dense_layers: int = 0        # leading dense layers (kimi-style)
    moe_dense_ff: int = 0                  # d_ff of the dense layers

    # --- SSM (mamba2 / SSD) ---
    ssm_state_dim: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    ssm_conv_width: int = 4

    # --- hybrid (recurrentgemma) ---
    # pattern of block kinds repeated over depth, e.g. ("rglru","rglru","attn")
    hybrid_pattern: Tuple[str, ...] = ()
    rglru_width: int = 0                   # 0 -> d_model
    rglru_conv_width: int = 4

    # --- cross attention (vlm / encdec decoder) ---
    cross_attn_period: int = 0             # every k-th layer is cross-attn
    encoder_seq_len: int = 0               # stub frontend sequence length

    # --- encoder-decoder (T5; paper benchmark family) ---
    num_decoder_layers: int = 0

    # --- input modality ---
    # "tokens": int32 ids; "embeddings": precomputed frames/patches (stub)
    input_kind: str = "tokens"

    # --- misc ---
    act: str = "silu"                      # silu | gelu
    mlp_glu: bool = True                   # gated MLP (False: classic 2-layer)
    max_position: int = 32768              # learned-pos table (non-RoPE archs)
    scale_embed: bool = False              # gemma-style sqrt(d_model) scaling
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # Extra normalisation flavour: gemma2 uses pre+post norms per block.
    post_block_norm: bool = False

    # ---- derived ----
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up so the 16-way model axis always divides it."""
        return int(math.ceil(self.vocab_size / 256) * 256)

    @property
    def is_encoder_only(self) -> bool:
        return not self.causal

    @property
    def has_decode(self) -> bool:
        """Encoder-only models have no autoregressive decode step."""
        return self.causal

    @property
    def supports_long_context(self) -> bool:
        """True if every block is sub-quadratic (SSM / linear recurrence /
        bounded-window attention). Pure full-attention archs skip long_500k."""
        if self.family == "ssm":
            return True
        if self.hybrid_pattern:
            # hybrid: attention blocks must be sliding-window
            return self.sliding_window > 0
        return False

    def layer_kind(self, i: int) -> str:
        """Block kind at depth i: 'attn' | 'rglru' | 'ssm' | 'cross'."""
        if self.family == "ssm":
            return "ssm"
        if self.hybrid_pattern:
            return self.hybrid_pattern[i % len(self.hybrid_pattern)]
        if self.cross_attn_period and (i % self.cross_attn_period
                                       == self.cross_attn_period - 1):
            return "cross"
        return "attn"

    def is_local_layer(self, i: int) -> bool:
        if self.sliding_window <= 0:
            return False
        if self.local_global_period <= 0:
            return True  # all layers local (recurrentgemma attn blocks)
        return i % self.local_global_period != self.local_global_period - 1

    def is_moe_layer(self, i: int) -> bool:
        return (self.moe_num_experts > 0) and (i >= self.moe_first_dense_layers)

    def validate(self) -> "ModelConfig":
        assert self.family in FAMILIES, self.family
        if self.family != "ssm":
            assert self.num_heads >= 1
            if self.num_kv_heads:
                assert self.num_heads % self.num_kv_heads == 0
        if self.moe_num_experts:
            assert 0 < self.moe_top_k <= self.moe_num_experts
        if self.hybrid_pattern:
            assert all(k in ("rglru", "attn") for k in self.hybrid_pattern)
        return self


@dataclass(frozen=True)
class SpoolIoConfig:
    """Declarative selection of the activation spool's storage stack
    (repro.io). Purely data — `repro.io.build_backend` turns it into a
    `StorageBackend`, and `core.staged.StagedTrainer` threads it through
    to the spool.

    backend: "fs" (one directory / one SSD), "striped" (round-robin
    chunks across `stripe_dirs`, a multi-SSD array), "mem" (host RAM),
    "tiered" (RAM under `host_mem_budget_bytes`, spilling to a lower
    fs/striped backend), "managed" (the `repro.cache.CacheManager`
    storage brain: class- and reuse-distance-aware placement over the
    same host-RAM-bounded-over-SSD hierarchy, with background promotion
    and failing-SSD fallback; `host_mem_budget_bytes` is its pinned-host
    bound, `cache_ssd` optionally picks the SSD tier by spec string, and
    `cache_promote_depth` bounds promotions per reuse-horizon hint), or
    "aio" (O_DIRECT-style direct I/O from a pooled aligned buffer with
    `queue_depth` concurrent segment submission; falls back to
    buffered+fdatasync+fadvise where the filesystem rejects O_DIRECT).

    The data-plane knobs apply to every backend: `alignment` and
    `pool_bytes` size the shared `AlignedBufferPool` that loads (and
    aio stores) stage through; `queue_depth` is the aio backend's
    per-blob submission depth.

    host_offload: what the jit engine routes through the spool —
    "none" (spool unused by the jit engine; the staged engine ignores
    this field), "opt_state" (optimizer moments live on the selected
    backend *between* steps, 10Cache-style), or "activations"
    (per-layer residuals stream through the backend *inside* the jitted
    step via the repro.core.hooks io_callback path). On a multi-device
    mesh the "activations" mode is SPMD-sharded: every device's host
    callback hands the spool only its local residual shard under
    shard-qualified lease keys (``jit{step}/s{shard}``).

    dedupe_replicas: mesh-aware offload only — when part of the mesh
    merely replicates a segment's residuals (e.g. tensor-parallel ranks
    of a batch-sharded tensor), store ONE copy per replica group and
    count backward fetches down by the replica count (True, default)
    instead of writing one copy per device (False)."""
    backend: str = "fs"
    directory: Optional[str] = None        # None -> fresh temp dir
    stripe_dirs: Tuple[str, ...] = ()
    stripe_chunk_bytes: int = 4 << 20
    codec: str = "raw"                     # raw | zlib | byteplane
    host_mem_budget_bytes: int = 256 << 20
    store_threads: int = 4
    load_threads: int = 4
    bandwidth_limit: Optional[float] = None
    host_offload: str = "none"      # none | opt_state | activations (jit)
    # jit engine: overlap the optimizer step with backward — per-layer
    # eager updates with moment fetch/update/stage hidden under compute
    # (repro.optim.overlap.OptBridge). Needs a clip-free optimizer.
    opt_overlap: bool = False
    dedupe_replicas: bool = True    # mesh: store replicated shards once
    # --- data-plane knobs (buffer pool / direct I/O) ---
    alignment: int = 4096           # pool + O_DIRECT alignment
    queue_depth: int = 4            # aio: concurrent segments per blob
    pool_bytes: int = 256 << 20     # idle cap of the aligned pool
    # --- cache-manager knobs (backend == "managed") ---
    cache_ssd: Optional[str] = None  # SSD-tier spec; None -> fs/striped
    cache_promote_depth: int = 2     # promotions per reuse-horizon hint
    # --- resilience knobs (repro.resilience) ---
    retry_attempts: int = 3          # total tries per spool I/O op
    retry_backoff_s: float = 0.01    # first retry delay (doubles per try)
    retry_backoff_max_s: float = 0.25
    on_fetch_fail: str = "recompute"  # recompute | raise

    def validate(self) -> "SpoolIoConfig":
        # `backend` may be a bare kind or a full repro.io.factory spec
        # string ("fault@2:striped:/a,/b"); validate the outermost kind
        kind = self.backend.split(":", 1)[0].split("@", 1)[0]
        assert kind in ("fs", "striped", "mem", "tiered",
                        "managed", "aio", "fault"), self.backend
        assert self.cache_promote_depth >= 0, self.cache_promote_depth
        assert self.stripe_chunk_bytes > 0
        assert self.host_mem_budget_bytes >= 0
        assert self.host_offload in ("none", "opt_state", "activations"), \
            self.host_offload
        assert isinstance(self.opt_overlap, bool), self.opt_overlap
        assert isinstance(self.dedupe_replicas, bool), self.dedupe_replicas
        import mmap
        assert self.alignment > 0 and \
            (self.alignment & (self.alignment - 1)) == 0, \
            f"alignment must be a power of two, got {self.alignment}"
        assert self.alignment <= mmap.PAGESIZE, \
            (f"alignment {self.alignment} exceeds the page size "
             f"{mmap.PAGESIZE} that mmap-backed pool buffers guarantee")
        assert self.queue_depth >= 1, self.queue_depth
        assert self.pool_bytes >= 0, self.pool_bytes
        assert self.retry_attempts >= 1, self.retry_attempts
        assert self.retry_backoff_s >= 0.0, self.retry_backoff_s
        assert self.retry_backoff_max_s >= 0.0, self.retry_backoff_max_s
        assert self.on_fetch_fail in ("recompute", "raise"), \
            self.on_fetch_fail
        if self.backend == "striped":
            assert len(self.stripe_dirs) != 1, \
                "striping across one directory is just 'fs'"
        return self


@dataclass(frozen=True)
class ShapeConfig:
    """One (input-shape) cell: training or serving geometry."""
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


# The four assigned shapes (identical across the 10 LM-family archs).
SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def reduced(cfg: ModelConfig, *, layers: int = 2, d_model: int = 64,
            heads: int = 4, kv_heads: int = 0, d_ff: int = 128,
            vocab: int = 512, experts: int = 4) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    kv = kv_heads or max(1, min(cfg.num_kv_heads, heads) if cfg.num_kv_heads else heads)
    while heads % kv:
        kv -= 1
    updates = dict(
        num_layers=layers, d_model=d_model, num_heads=heads,
        num_kv_heads=kv, d_ff=d_ff, vocab_size=vocab,
        head_dim=d_model // heads,
    )
    if cfg.moe_num_experts:
        updates.update(moe_num_experts=experts,
                       moe_top_k=min(cfg.moe_top_k, experts),
                       moe_shared_experts=min(cfg.moe_shared_experts, 1),
                       moe_first_dense_layers=min(cfg.moe_first_dense_layers, 1),
                       moe_dense_ff=d_ff)
    if cfg.family == "ssm":
        updates.update(ssm_state_dim=16, ssm_head_dim=16, ssm_chunk=32)
    if cfg.rglru_width:
        updates.update(rglru_width=d_model)
    if cfg.sliding_window:
        updates.update(sliding_window=min(cfg.sliding_window, 16))
    if cfg.encoder_seq_len:
        updates.update(encoder_seq_len=16)
    if cfg.num_decoder_layers:
        updates.update(num_decoder_layers=max(1, layers // 2))
    if cfg.cross_attn_period:
        updates.update(cross_attn_period=2)
    return dataclasses.replace(cfg, **updates).validate()
