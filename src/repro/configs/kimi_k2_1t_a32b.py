"""kimi-k2-1t-a32b [moe] — trillion-parameter MoE, 384 experts top-8 + 1 shared.
[arXiv:2501.kimi2 (paper-table)]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=2048,                   # per-expert FF width
    vocab_size=163840,
    moe_num_experts=384,
    moe_top_k=8,
    moe_shared_experts=1,
    moe_first_dense_layers=1,    # leading dense layer (DeepSeek/Kimi style)
    moe_dense_ff=18432,
    act="silu",
).validate()
