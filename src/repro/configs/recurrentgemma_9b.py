"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 1 attn : 2 recurrent.
[arXiv:2402.19427 (Griffin)]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,              # MQA on the attention blocks
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    sliding_window=2048,         # attention blocks are local-only
    hybrid_pattern=("rglru", "rglru", "attn"),
    rglru_width=4096,
    rglru_conv_width=4,
    act="gelu",
    tie_embeddings=True,
    scale_embed=True,
).validate()
