from repro.runtime.trainer import StragglerWatchdog, TrainLoop, TrainState

__all__ = ["TrainLoop", "TrainState", "StragglerWatchdog"]
