"""Fault-tolerant training driver.

Responsibilities (the ones a 1000-node fleet actually needs):
  * checkpoint/restart — periodic async checkpoints (model + optimizer +
    data cursor), `--resume` picks up the latest committed step;
  * preemption handling — SIGTERM/SIGINT trap requests a final checkpoint
    at the next step boundary, then exits cleanly (the cluster scheduler's
    contract);
  * straggler mitigation — per-step wall-time watchdog keeps a rolling
    median; steps slower than `threshold x median` are recorded and
    surfaced through a callback (on a real fleet this feeds the
    repair/reschedule controller; here the hook is unit-tested directly);
  * elastic restart — restore() takes the *current* mesh's shardings, so
    a checkpoint taken on one topology restores onto another;
  * metrics — JSONL lines per step (loss, step time, tokens/s);
  * host offload — with an `ActivationSpool` attached (built from a
    `SpoolIoConfig` by `TrainSession`), two modes share the spool's
    backend/codec selection with the staged engine:
      - "opt_state": the optimizer state is staged through the storage
        backend between steps — offloaded asynchronously after the
        update, fetched (with tensor forwarding) just before the next
        one (10Cache-style optimizer-state tiering);
      - "activations": per-layer residuals stream through the backend
        *inside* the jitted step via the repro.core.hooks io_callback
        path — the step_fn owns that traffic (the loop only holds the
        spool for stats/teardown), so the two modes coexist as
        alternatives on one spool.
"""
from __future__ import annotations

import json
import os
import signal
import statistics
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterable, List, Optional

import numpy as np

import jax

from repro import obs
from repro.ckpt.checkpoint import (CheckpointManager, restore_train_state,
                                   save_train_state)


@dataclass
class TrainState:
    step: int
    params: Any
    opt_state: Any


def batch_tokens(batch) -> int:
    """Tokens a batch contributes to throughput. With labels present
    only real targets count (labels >= 0) — shape products overcount
    padded positions. Returns 0 when the batch carries no tokens."""
    if isinstance(batch, dict) and "labels" in batch:
        return int(np.sum(np.asarray(batch["labels"]) >= 0))
    if isinstance(batch, dict) and "tokens" in batch:
        return int(np.prod(batch["tokens"].shape))
    return 0


class StragglerWatchdog:
    """Rolling-median step-time monitor."""

    def __init__(self, *, window: int = 32, threshold: float = 2.0,
                 on_straggler: Optional[Callable[[int, float, float],
                                                 None]] = None):
        self.window = window
        self.threshold = threshold
        self.on_straggler = on_straggler
        self.times: List[float] = []
        self.flagged: List[Dict] = []

    def record(self, step: int, dt: float) -> bool:
        history = self.times[-self.window:]
        is_straggler = False
        if len(history) >= 8:
            med = statistics.median(history)
            if dt > self.threshold * med:
                is_straggler = True
                self.flagged.append({"step": step, "dt": dt, "median": med})
                if self.on_straggler:
                    self.on_straggler(step, dt, med)
        self.times.append(dt)
        return is_straggler


class TrainLoop:
    def __init__(self, *, step_fn: Callable, init_state: TrainState,
                 loader, ckpt_dir: str, ckpt_every: int = 100,
                 keep_last: int = 3, metrics_path: Optional[str] = None,
                 watchdog: Optional[StragglerWatchdog] = None,
                 shardings: Any = None,
                 spool: Any = None,
                 host_offload: Any = False,
                 opt_bridge: Any = None,
                 on_step: Optional[Callable[[int, float, Any, Any],
                                            None]] = None,
                 install_signal_handlers: bool = False):
        self.step_fn = step_fn
        self.state = init_state
        self.loader = loader
        self.ckpt = CheckpointManager(ckpt_dir, keep_last=keep_last)
        self.ckpt_every = ckpt_every
        self.metrics_path = metrics_path
        self.watchdog = watchdog or StragglerWatchdog()
        self.shardings = shardings
        # host offload: the spool is owned by the caller (TrainSession).
        # Mode "opt_state" leases per-step records here; "activations"
        # is driven from inside step_fn (repro.core.hooks) and the loop
        # only carries the spool. Legacy bool maps onto "opt_state".
        if isinstance(host_offload, bool):
            host_offload = "opt_state" if host_offload else "none"
        assert host_offload in ("none", "opt_state", "activations"), \
            host_offload
        # Eager overlap (repro.optim.overlap.OptBridge): the bridge owns
        # per-layer opt-state placement, so the serial whole-state
        # staging path is retired for this loop — the step_fn's grad
        # taps drive all opt I/O and the loop's opt_state is a light
        # (step, None, None) husk the bridge can rematerialize.
        self.opt_bridge = opt_bridge
        if opt_bridge is not None and host_offload == "opt_state":
            host_offload = "none"
        self.spool = spool
        self.host_offload = (host_offload if spool is not None
                             else "none")
        self.on_step = on_step
        self._opt_tx = None          # live SpoolStepTransaction, if any
        self._preempted = False
        self._metrics_f = open(metrics_path, "a") if metrics_path else None
        if install_signal_handlers:
            for sig in (signal.SIGTERM, signal.SIGINT):
                signal.signal(sig, self._on_preempt)

    # ------------------------------------------------------------ hooks

    def _on_preempt(self, signum, frame):
        # async-signal-safe: just set a flag; the loop checkpoints at the
        # next step boundary (the paper's framework-interop requirement
        # maps here to not corrupting in-flight async spools).
        self._preempted = True

    def request_preemption(self):
        """Test hook: simulate the scheduler's SIGTERM."""
        self._preempted = True

    # ----------------------------------------------- host offload (jit)

    def _acquire_opt_state(self):
        """The optimizer state, fetched back from the spool if the
        previous step staged it out (forwarding applies: a store still
        in flight is upgraded in memory, not re-read)."""
        if self._opt_tx is None:
            return self.state.opt_state
        tx, self._opt_tx = self._opt_tx, None
        with obs.span("engine.opt_fetch", cat="engine",
                      step=self.state.step):
            opt_state = tx.fetch(0)
        tx.close()                  # drops the record + deletes the blob
        return opt_state

    def _stage_opt_state(self, opt_state, step: int):
        """Async-offload the fresh optimizer state through the spool;
        returns what TrainState should hold (None while spooled — the
        spool owns the only strong reference until the next acquire)."""
        if self.host_offload != "opt_state":
            return opt_state
        with obs.span("engine.opt_stage", cat="engine", step=step):
            tx = self.spool.step(f"opt{step}")
            tx.offload(0, opt_state)
        self._opt_tx = tx
        return None

    # ------------------------------------------------------- checkpoints

    def _save(self, final: bool = False):
        opt_state = self.state.opt_state
        if self.opt_bridge is not None and self.opt_bridge.seeded:
            # per-layer moments live on the spool (plus the bridge's
            # in-memory rest-of-tree moments) — reassemble the full
            # OptState non-consumingly for the checkpoint
            opt_state = self.opt_bridge.materialize()
        elif opt_state is None and self._opt_tx is not None:
            # staged out between steps: materialize non-consumingly —
            # peek() must not cancel the queued store, or the next
            # step's fetch would find neither arrays nor blob
            opt_state = self._opt_tx.peek(0)
        save_train_state(self.ckpt, self.state.step, self.state.params,
                         opt_state, self.loader, final=final)

    def resume(self) -> bool:
        """Restore the latest checkpoint if present. Returns True if
        restored. Reshards onto the current mesh via self.shardings."""
        restored = restore_train_state(
            self.ckpt, self.state.params, self.state.opt_state,
            self.loader, shardings=self.shardings)
        if restored is None:
            return False
        self.state = TrainState(*restored)
        return True

    # ------------------------------------------------------------- loop

    def run(self, num_steps: int) -> TrainState:
        it = iter(self.loader)
        target = self.state.step + num_steps
        while self.state.step < target and not self._preempted:
            try:
                batch = next(it)
            except StopIteration:
                # a finite loader ran dry: end the loop cleanly — the
                # final checkpoint and the staged-opt-state
                # rematerialization below must still run
                break
            t0 = time.perf_counter()
            with obs.span("engine.step", cat="engine",
                          step=self.state.step, engine="jit"):
                params, opt_state, metrics = self.step_fn(
                    self.state.params, self._acquire_opt_state(), batch)
                jax.block_until_ready(jax.tree.leaves(params)[0])
            dt = time.perf_counter() - t0
            opt_state = self._stage_opt_state(opt_state,
                                              self.state.step + 1)
            self.state = TrainState(self.state.step + 1, params, opt_state)
            self.watchdog.record(self.state.step, dt)
            self._log(metrics, dt, batch)
            if self.on_step:
                self.on_step(self.state.step, dt, metrics, batch)
            if self.ckpt_every and \
                    self.state.step % self.ckpt_every == 0:
                self._save()
        # rematerialize a staged-out optimizer state before the final
        # checkpoint / before handing the state back
        if self._opt_tx is not None:
            self.state = TrainState(self.state.step, self.state.params,
                                    self._acquire_opt_state())
        if self.opt_bridge is not None and self.opt_bridge.seeded:
            self.state = TrainState(self.state.step, self.state.params,
                                    self.opt_bridge.materialize())
        self._save(final=True)
        return self.state

    def _log(self, metrics, dt, batch):
        if self._metrics_f is None:
            return
        rec = {"step": self.state.step, "step_time_s": dt}
        tokens = batch_tokens(batch)
        if tokens:
            rec["tokens_per_s"] = tokens / dt
        for k, v in (metrics or {}).items():
            try:
                rec[k] = float(v)
            except (TypeError, ValueError):
                pass
        self._metrics_f.write(json.dumps(rec) + "\n")
        self._metrics_f.flush()

    def close(self):
        if self._opt_tx is not None:
            self._opt_tx.close()
            self._opt_tx = None
        if self._metrics_f:
            self._metrics_f.close()
        self.ckpt.wait()
